"""Sharding-agnostic checkpointing: atomic, async, keep-k.

Design (the orbax pattern, dependency-free):

  * params/opt-state are flattened to named leaves ("layers/attn/wq", ...)
    and written as raw .npy blobs + a JSON manifest with step metadata.
  * arrays are host-gathered to their LOGICAL (unsharded) shape, so a
    checkpoint written on one mesh restores onto ANY mesh — elastic
    restarts (runtime/elastic.py) just re-shard at load.
  * writes go to ``<dir>/step_<k>.tmp`` then ``os.replace`` to the final
    name — a crash mid-write never corrupts the latest checkpoint.
  * an async writer thread overlaps serialization with training; ``wait``
    joins before the next save (single-buffered, like orbax's async).
  * keep-last-k + keep-best (by a metric the caller passes) retention.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
from typing import Any, Callable

import jax
import numpy as np

PyTree = Any


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
            arr = arr.astype(np.float32)  # lossless; .npy can't store bf16
        flat[name] = arr
    return flat


def _unflatten_like(template: PyTree, flat: dict[str, np.ndarray]) -> PyTree:
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        if name not in flat:
            raise KeyError(f"checkpoint missing leaf {name}")
        arr = flat[name]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {name}: ckpt {arr.shape} vs "
                f"model {leaf.shape}")
        import jax.numpy as jnp
        leaves.append(jnp.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str | pathlib.Path, *, keep_last: int = 3,
                 keep_best: int = 1, async_write: bool = True):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self.keep_best = keep_best
        self.async_write = async_write
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: PyTree, *, metric: float | None = None,
             extra: dict | None = None):
        flat = _flatten(tree)  # host-gather on the caller thread (cheap)
        self.wait()

        def write():
            try:
                self._write(step, flat, metric, extra or {})
            except Exception as e:  # surfaced on next wait()
                self._error = e

        if self.async_write:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def _write(self, step: int, flat: dict, metric, extra):
        tmp = self.dir / f"step_{step:08d}.tmp"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir()
        manifest = {"step": step, "metric": metric, "extra": extra,
                    "leaves": {}}
        for name, arr in flat.items():
            fname = name.replace("/", "__") + ".npy"
            np.save(tmp / fname, arr)
            manifest["leaves"][name] = {
                "file": fname, "shape": list(arr.shape),
                "dtype": str(arr.dtype)}
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic publish
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # -- restore -----------------------------------------------------------
    def steps(self) -> list[int]:
        return sorted(int(p.name.split("_")[1]) for p in self.dir.glob(
            "step_*") if p.is_dir() and not p.name.endswith(".tmp"))

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def manifest(self, step: int) -> dict:
        return json.loads(
            (self.dir / f"step_{step:08d}" / "manifest.json").read_text())

    def restore(self, step: int, template: PyTree,
                shardings: PyTree | None = None) -> PyTree:
        """Load logical arrays and (optionally) place them sharded.

        ``shardings`` may target a DIFFERENT mesh than the one the
        checkpoint was saved under — this is the elastic-restart path.
        """
        d = self.dir / f"step_{step:08d}"
        man = self.manifest(step)
        flat = {name: np.load(d / meta["file"])
                for name, meta in man["leaves"].items()}
        tree = _unflatten_like(template, flat)
        if shardings is not None:
            tree = jax.tree.map(jax.device_put, tree, shardings)
        return tree

    # -- retention ------------------------------------------------------------
    def _gc(self):
        steps = self.steps()
        if len(steps) <= self.keep_last:
            return
        # collect best-k by metric (None metrics never counted as best)
        metrics = {}
        for s in steps:
            try:
                metrics[s] = self.manifest(s).get("metric")
            except Exception:
                metrics[s] = None
        scored = [s for s in steps if metrics[s] is not None]
        best = set(sorted(scored, key=lambda s: metrics[s])
                   [: self.keep_best])
        keep = set(steps[-self.keep_last:]) | best
        for s in steps:
            if s not in keep:
                shutil.rmtree(self.dir / f"step_{s:08d}",
                              ignore_errors=True)
