"""Error-feedback int8 gradient compression for the DP all-reduce.

At 512 chips the llama-3B gradient all-reduce moves ~6.4 GiB/step/device
(bf16); int8 with per-block scales cuts that 2x with negligible quality
loss WHEN error feedback is applied: the quantization residual is carried
into the next step (Seide et al. 2014; standard in large-scale setups).

``compressed_psum`` is built for shard_map'd training loops: quantize ->
psum int32 accumulators -> dequantize, with the residual returned to the
caller to add into the next step's gradients. A pure-jit variant
(``compress / decompress``) is exposed for the checkpoint-size use-case.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array

_BLOCK = 256


class Compressed(NamedTuple):
    q: Array  # int8 payload, padded to _BLOCK
    scale: Array  # f32 per-block scales
    n: int  # original length


def compress(x: Array) -> Compressed:
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % _BLOCK
    flat = jnp.pad(flat, (0, pad)).reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / 127.0
    safe = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(flat / safe), -127, 127).astype(jnp.int8)
    return Compressed(q=q, scale=scale, n=n)


def decompress(c: Compressed, shape, dtype) -> Array:
    flat = (c.q.astype(jnp.float32) * c.scale).reshape(-1)[: c.n]
    return flat.reshape(shape).astype(dtype)


def quantization_residual(x: Array, c: Compressed) -> Array:
    return x - decompress(c, x.shape, x.dtype)


def compressed_psum(grads, residuals, axis_name: str):
    """Error-feedback int8 psum over `axis_name` (inside shard_map).

    grads/residuals: pytrees of per-device partial gradients. Returns
    (mean_grads, new_residuals). The int8 payloads are summed in int32 to
    avoid overflow across <= 2^23 devices.
    """

    def one(g, r):
        g = g + r.astype(g.dtype)  # error feedback
        c = compress(g)
        # re-quantize every device onto a COMMON per-block scale (the
        # ring-wide max) so int32 summation is exact w.r.t. that scale
        common = jax.lax.pmax(c.scale, axis_name)
        ratio = c.scale / jnp.maximum(common, 1e-30)
        q2 = jnp.clip(jnp.round(c.q.astype(jnp.float32) * ratio),
                      -127, 127).astype(jnp.int32)
        summed = jax.lax.psum(q2, axis_name)
        nparts = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        mean = (summed.astype(jnp.float32) * common / nparts)
        mean = mean.reshape(-1)[: c.n].reshape(g.shape).astype(g.dtype)
        # residual = what I handed in minus what the sum credits me with
        mine = (q2.astype(jnp.float32) * common).reshape(-1)[: c.n]
        new_r = g - mine.reshape(g.shape).astype(g.dtype)
        return mean, new_r

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    means = jax.tree.unflatten(treedef, [m for m, _ in out])
    resid0 = jax.tree.unflatten(treedef, [r for _, r in out])
    return means, resid0


def init_residuals(grads_template):
    return jax.tree.map(jnp.zeros_like, grads_template)
