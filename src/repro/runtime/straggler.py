"""Straggler / hang mitigation for the host-side training loop.

TPU pods fail in two modes the loop must survive: a *slow* step (network
blip, preemption warning, input stall) and a *hung* step (device wedged).
The watchdog times every step against a deadline derived from a running
percentile of recent step times; on breach it fires a callback that can
  * skip the step deterministically (data/pipeline.py Prefetcher.skip —
    every host skips the same step id, keeping data order consistent),
  * checkpoint-and-exit so the scheduler can restart elastically
    (runtime/elastic.py).

Used by launch/train.py; unit-tested with fake clocks in
tests/test_runtime.py.
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Callable


class StepWatchdog:
    def __init__(self, *, window: int = 50, multiplier: float = 3.0,
                 min_deadline: float = 10.0,
                 on_breach: Callable[[int, float], None] | None = None):
        self.window = window
        self.multiplier = multiplier
        self.min_deadline = min_deadline
        self.on_breach = on_breach
        self._times: collections.deque = collections.deque(maxlen=window)
        self._timer: threading.Timer | None = None
        self._breached: list[tuple[int, float]] = []

    @property
    def deadline(self) -> float:
        if not self._times:
            return float("inf")  # no baseline yet -> never fire
        baseline = sorted(self._times)[len(self._times) // 2]  # median
        return max(self.min_deadline, self.multiplier * baseline)

    def start_step(self, step: int):
        self.cancel()
        d = self.deadline
        if d == float("inf"):
            return

        def fire():
            self._breached.append((step, d))
            if self.on_breach:
                self.on_breach(step, d)

        self._timer = threading.Timer(d, fire)
        self._timer.daemon = True
        self._timer.start()

    def end_step(self, seconds: float):
        self.cancel()
        self._times.append(seconds)

    def cancel(self):
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None

    @property
    def breaches(self) -> list[tuple[int, float]]:
        return list(self._breached)


class StepTimer:
    """Context manager wiring the watchdog into the train loop."""

    def __init__(self, watchdog: StepWatchdog, step: int):
        self.watchdog = watchdog
        self.step = step

    def __enter__(self):
        self.t0 = time.perf_counter()
        self.watchdog.start_step(self.step)
        return self

    def __exit__(self, *exc):
        self.watchdog.end_step(time.perf_counter() - self.t0)
        return False
