from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.straggler import StepTimer, StepWatchdog
from repro.runtime import compression, elastic

__all__ = ["CheckpointManager", "StepTimer", "StepWatchdog",
           "compression", "elastic"]
